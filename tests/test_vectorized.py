"""PR-10 vectorized array-kernel backend (DESIGN.md S16).

Contracts under test:

1. The three-engine lattice is bit-identical — latency, done, delivered
   AND the full EnergyLedger: vectorized window kernels (K1 closed form,
   K2 column replay) vs the heap engine over every fig7-12 plan shape,
   and the K3 DAG wavefront kernel vs heap over the shared collective /
   faulted-collective corpora and seeded random programs.
2. Fallback is clean: programs outside every lowered family raise
   UnvectorizableProgram from ``lower_program``, are attributed in
   VECTOR_STATS, and ``run_program(engine="auto")`` still answers them
   (compiled/heap) with the oracle result.
3. The batching axes fill SIM_CACHE with the same bits the serial path
   would have produced: ``prefetch_windows`` (windows x candidate
   mappings) and the mapper search are invisible to results.
4. VECTOR_STATS mirrors ROUTE_STATS/COST_STATS: observable, resettable,
   attributed per fallback reason.
5. ``benchmarks/run.py`` can never silently overwrite a recorded
   BENCH_<n>.json trajectory point (the numbering has gaps — no
   BENCH_6).
"""
import dataclasses
import os
import random
import sys

import pytest

from repro.analysis.corpus import (collective_programs,
                                   faulted_collective_programs,
                                   ws_plan_shapes)
from repro.core.noc import (NocConfig, SIM_CACHE, compiled_disabled,
                            fresh_sim_cache, sim_cache_disabled,
                            simulate_layer)
from repro.core.noc import vectorized
from repro.core.noc.collective.engine import run_program
from repro.core.noc.collective.schedule import (plan_collective,
                                                ws_round_program)
from repro.core.noc.traffic import clear_compiled_caches
from repro.core.noc.vectorized import (UnvectorizableProgram, VECTOR_STATS,
                                       lower_program, prefetch_windows,
                                       reset_vector_stats, run_vectorized,
                                       vector_stats, vectorized_disabled,
                                       window_family, window_result)
from repro.core.workloads import VGG16

CFG = NocConfig()


def _ld(ledger):
    return dataclasses.asdict(ledger)


def _heap(prog, cfg):
    return run_program(prog, cfg, engine="heap")


# --------------------------------------------------------------------------- #
# 1. Oracle equivalence: window kernels (K1/K2) over the fig7-12 corpus
# --------------------------------------------------------------------------- #
def test_window_kernels_bit_identical_to_heap_on_fig_shapes():
    """Every fig7-12 plan shape x window length: the closed-form (K1) or
    column-replay (K2) window result equals the heap engine bit for bit —
    latency AND the full EnergyLedger."""
    answered = {"pipeline": 0, "chain": 0}
    for shape in ws_plan_shapes(quick=True, cfg=CFG):
        for window in (1, 4):
            vec = window_result(CFG, shape["mode"], window, shape["g"],
                                shape["p"], shape["gather_flits"],
                                shape["unicast_flits"], shape["e_pes"])
            if vec is None:          # fallback contract covered below
                continue
            answered[window_family(shape["mode"], shape["p"])] += 1
            prog = ws_round_program(
                CFG, shape["mode"], window, g=shape["g"], p=shape["p"],
                gather_flits=shape["gather_flits"],
                unicast_flits=shape["unicast_flits"], e_pes=shape["e_pes"])
            heap = _heap(prog, CFG)
            assert vec[0] == heap.latency_cycles, shape
            assert _ld(vec[1]) == _ld(heap.ledger), shape
    # Both families must actually run on the paper's own shapes.
    assert answered["pipeline"] > 5 and answered["chain"] > 5, answered


# --------------------------------------------------------------------------- #
# 1b. Oracle equivalence: DAG wavefront kernel (K3) over the collective
#     corpora — clean and fault-repaired
# --------------------------------------------------------------------------- #
def test_run_vectorized_matches_heap_on_collective_corpus():
    reset_vector_stats()
    lowered = 0
    for case, cfg, prog in collective_programs():
        try:
            latency, ledger, done, delivered = run_vectorized(prog, cfg)
        except UnvectorizableProgram:
            continue
        lowered += 1
        heap = _heap(prog, cfg)
        assert latency == heap.latency_cycles, case
        assert done == heap.done, case
        assert delivered == heap.delivered, case
        assert _ld(ledger) == _ld(heap.ledger), case
    assert lowered > 0
    assert VECTOR_STATS["programs_lowered"] == lowered


def test_run_vectorized_matches_heap_on_faulted_corpus():
    lowered = 0
    for case, cfg, _faults, prog in faulted_collective_programs(quick=True):
        try:
            latency, ledger, done, delivered = run_vectorized(prog, cfg)
        except UnvectorizableProgram:
            continue
        lowered += 1
        heap = _heap(prog, cfg)
        assert (latency, done, delivered) == \
            (heap.latency_cycles, heap.done, heap.delivered), case
        assert _ld(ledger) == _ld(heap.ledger), case
    assert lowered > 0          # detour-repaired trees still lower


def test_engine_auto_dispatch_is_invisible_for_collectives():
    """run_program's vectorized-first dispatch returns the oracle bits
    whether the program lowers (K3) or falls back (compiled/heap)."""
    for case, cfg, prog in collective_programs():
        auto = run_program(prog, cfg, engine="auto")
        heap = _heap(prog, cfg)
        assert auto.latency_cycles == heap.latency_cycles, case
        assert auto.done == heap.done, case
        assert auto.delivered == heap.delivered, case
        assert _ld(auto.ledger) == _ld(heap.ledger), case


# --------------------------------------------------------------------------- #
# 1c. Seeded random programs
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", [0, 7, 23])
def test_random_collectives_auto_equals_heap(seed):
    rng = random.Random(seed)
    nodes = [(x, y) for x in range(4) for y in range(4)]
    for _ in range(8):
        parts = rng.sample(nodes, rng.randint(2, 10))
        op = rng.choice(("reduce", "broadcast", "gather", "allreduce"))
        semantics = rng.choice(("ina", "eject_inject"))
        algorithm = "rs_ag" if (op == "allreduce" and rng.random() < 0.5) \
            else "reduce_bcast"
        payload = rng.choice((32.0, 128.0, 512.0, 1024.0))
        prog = plan_collective(op, parts, payload, CFG,
                               algorithm=algorithm, semantics=semantics)
        auto = run_program(prog, CFG, engine="auto")
        heap = _heap(prog, CFG)
        assert auto.latency_cycles == heap.latency_cycles, (op, semantics)
        assert auto.delivered == heap.delivered, (op, semantics)
        assert _ld(auto.ledger) == _ld(heap.ledger), (op, semantics)


# --------------------------------------------------------------------------- #
# 2. Fallback contract
# --------------------------------------------------------------------------- #
def test_inexpressible_program_falls_back_and_is_attributed():
    """eject-inject trees serialize distinct packets through shared
    ejection ports — real contention, outside every lowered family.
    ``lower_program`` must refuse (attributed in VECTOR_STATS) and the
    auto engine must still produce the oracle bits."""
    parts = [(x, y) for x in range(4) for y in range(4)]
    prog = plan_collective("reduce", parts, 512.0, CFG,
                           semantics="eject_inject")
    before = vector_stats()["fallbacks"]
    with pytest.raises(UnvectorizableProgram):
        lower_program(prog, CFG)
    assert vector_stats()["fallbacks"] == before + 1
    auto = run_program(prog, CFG, engine="auto")
    heap = _heap(prog, CFG)
    assert auto.latency_cycles == heap.latency_cycles
    assert _ld(auto.ledger) == _ld(heap.ledger)


def test_vectorized_disabled_restores_pr4_behaviour():
    assert vectorized.vectorized_enabled()
    with vectorized_disabled():
        assert not vectorized.vectorized_enabled()
        assert window_result(CFG, "ws_ina", 4, 8, 1, 2, 1, 2) is None
        with fresh_sim_cache():
            assert prefetch_windows(
                [(CFG, "ws_ina", 4, 8, 1, 2, 1, 2)]) == 0
    assert vectorized.vectorized_enabled()


# --------------------------------------------------------------------------- #
# 3. Batching axes: prefetch fills SIM_CACHE with the serial path's bits
# --------------------------------------------------------------------------- #
def test_prefetch_windows_matches_serial_window_results():
    keys = []
    for shape in ws_plan_shapes(quick=True, cfg=CFG)[:12]:
        for window in (2, 8):
            keys.append((CFG, shape["mode"], window, shape["g"],
                         shape["p"], shape["gather_flits"],
                         shape["unicast_flits"], shape["e_pes"]))
    serial = {}
    for key in keys:
        hit = window_result(*key)
        if hit is not None:
            serial[key] = hit
    reset_vector_stats()
    with fresh_sim_cache():
        answered = prefetch_windows(keys)
        assert answered == len(serial)
        stats = vector_stats()
        assert stats["windows_batched"] > 1        # the array pass ran
        for key, (latency, ledger) in serial.items():
            assert key in SIM_CACHE
            got_lat, got_ledger = SIM_CACHE.get(key)
            assert got_lat == latency
            assert _ld(got_ledger) == _ld(ledger)


def test_simulate_layer_identical_across_all_three_engines():
    layer = VGG16[8]
    for mode in ("ws_ina", "ws_noina", "os_gather"):
        with fresh_sim_cache(), compiled_disabled(), sim_cache_disabled():
            clear_compiled_caches()
            truth = simulate_layer(layer, mode, CFG, 2, sim_rounds=8)
        with fresh_sim_cache(), vectorized_disabled():
            clear_compiled_caches()
            compiled = simulate_layer(layer, mode, CFG, 2, sim_rounds=8)
        with fresh_sim_cache():
            clear_compiled_caches()
            vec = simulate_layer(layer, mode, CFG, 2, sim_rounds=8)
        for r in (compiled, vec):
            assert dataclasses.asdict(r) == dataclasses.asdict(truth), mode


def test_mapper_search_identical_with_and_without_vectorized():
    """The mapper's prefetch + rank/eval memos are invisible: identical
    schedules, ratios, and Pareto candidates either way."""
    from repro.core.workloads import mapper_workloads
    from repro.mapper import QUICK_MAPPER, search_network
    wl = mapper_workloads(conv=("alexnet",), transformers=())
    with fresh_sim_cache():
        clear_compiled_caches()
        vec = search_network("alexnet", wl["alexnet"], QUICK_MAPPER)
    with fresh_sim_cache(), vectorized_disabled():
        clear_compiled_caches()
        ref = search_network("alexnet", wl["alexnet"], QUICK_MAPPER)
    assert vec.latency_x == ref.latency_x
    assert vec.energy_x == ref.energy_x
    assert vec.best.hardware == ref.best.hardware
    assert [(c.hardware, c.latency_cycles, c.total_energy_pj)
            for c in vec.pareto] == \
        [(c.hardware, c.latency_cycles, c.total_energy_pj)
         for c in ref.pareto]


def test_hierarchy_cost_facade_identical_with_and_without_vectorized():
    from repro.core.noc.collective import cost as flat_cost
    from repro.core.noc.hierarchy import (hier_collective_cost,
                                          square_hier_mesh)
    hmesh = square_hier_mesh(4, chip_w=4, chip_h=4)
    flat_cost._simulate.cache_clear()           # defeat the facade memo
    clear_compiled_caches()
    vec = hier_collective_cost("allreduce", hmesh, 4096.0, semantics="ina")
    flat_cost._simulate.cache_clear()
    clear_compiled_caches()
    with vectorized_disabled():
        ref = hier_collective_cost("allreduce", hmesh, 4096.0,
                                   semantics="ina")
    assert dataclasses.asdict(vec) == dataclasses.asdict(ref)


# --------------------------------------------------------------------------- #
# 4. VECTOR_STATS observability
# --------------------------------------------------------------------------- #
def test_vector_stats_reset_and_summary_shape():
    reset_vector_stats()
    base = vector_stats()
    assert base["fallbacks"] == 0 and base["enabled"]
    window_result(CFG, "ws_ina", 4, 8, 1, 2, 1, 2)
    stats = vector_stats()
    assert stats["windows_closed_form"] == 1
    assert VECTOR_STATS["windows_closed_form"] == 1
    stats["windows_closed_form"] = 99           # snapshot is a copy
    assert VECTOR_STATS["windows_closed_form"] == 1
    reset_vector_stats()
    assert all(v == 0 for v in VECTOR_STATS.values())


def test_vectorized_module_is_in_determinism_lint_scope():
    from repro.analysis.lint import _DETERMINISM_SCOPE
    path = "src/repro/core/noc/vectorized.py"
    assert any(scope in path for scope in _DETERMINISM_SCOPE)


# --------------------------------------------------------------------------- #
# 5. BENCH numbering can never overwrite a recorded trajectory point
# --------------------------------------------------------------------------- #
def _bench_run_module():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks import run as bench_run
    return bench_run


def test_default_bench_path_skips_trajectory_gaps(tmp_path):
    """Given BENCH_{4,5,7}.json on disk (the real trajectory has no
    BENCH_6), the default must be BENCH_8.json — one past the highest,
    never the gap, never an existing file."""
    bench_run = _bench_run_module()
    for n in (4, 5, 7):
        (tmp_path / f"BENCH_{n}.json").write_text("{}")
    args = type("A", (), {"quick": False})()
    path = bench_run._default_bench_path(args, ["mapper_full"],
                                         root=str(tmp_path))
    assert os.path.basename(path) == "BENCH_8.json"
    assert not os.path.exists(path)


def test_default_bench_path_quick_and_partial_stay_out_of_trajectory(
        tmp_path):
    bench_run = _bench_run_module()
    (tmp_path / "BENCH_4.json").write_text("{}")
    quick = type("A", (), {"quick": True})()
    full = type("A", (), {"quick": False})()
    assert bench_run._default_bench_path(
        quick, ["mapper_full"], root=str(tmp_path)).endswith(
            os.path.join("results", "bench_snapshot.json"))
    assert bench_run._default_bench_path(
        full, ["tables"], root=str(tmp_path)).endswith(
            os.path.join("results", "bench_snapshot.json"))
    empty = tmp_path / "empty"
    empty.mkdir()
    assert os.path.basename(bench_run._default_bench_path(
        full, ["mapper_full"], root=str(empty))) == "BENCH_4.json"
