"""Collective NoC subsystem: tree invariants, planner equivalence, WS regression.

Three layers of guarantees:

1. Tree-builder invariants — every participant reached exactly once, root
   correct, union of routes is acyclic and connected.
2. Planner equivalence — the reduced value delivered by an allreduce is the
   full participant set for *every* participant, independent of algorithm
   (reduce+broadcast vs reduce-scatter+all-gather) and router semantics;
   total add count is always (P-1) x payload words.
3. Regression — the paper's WS+INA flow routed through the planner/engine
   reproduces the seed traffic generator's latency and energy exactly
   (pinned numbers captured from the pre-refactor simulator).
"""
import pytest

from repro.core.noc import NocConfig
from repro.core.noc.collective import (
    delivered_contribs, full_mesh, mesh_column, mesh_row, multicast_tree,
    plan_collective, psum_mode_costs, reduction_tree, run_program, segments)
from repro.core.noc.collective.schedule import (_words, program_pe_adds,
                                                program_reduce_words)
from repro.core.noc.power import ws_ina_improvement
from repro.core.workloads import ALEXNET, VGG16, WORKLOADS

CFG = NocConfig()

PARTICIPANT_SETS = {
    "full_mesh_4": full_mesh(4),
    "full_mesh_8": full_mesh(8),
    "row": mesh_row(8, 3),
    "column": mesh_column(8, 2),
    "subset": [(1, 1), (6, 6), (0, 3), (5, 2), (7, 0), (3, 7)],
}


# --------------------------------------------------------------------------- #
# 1. Tree-builder invariants
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", PARTICIPANT_SETS, ids=str)
@pytest.mark.parametrize("order", ["xy", "yx"])
@pytest.mark.parametrize("builder", [reduction_tree, multicast_tree],
                         ids=["reduce", "multicast"])
def test_tree_invariants(name, order, builder):
    parts = PARTICIPANT_SETS[name]
    root = sorted(parts)[len(parts) // 2]
    tree = builder(root, parts, order)
    tree.validate()          # connected, acyclic, |edges| = |nodes| - 1
    assert tree.root == root
    nodes = tree.nodes
    for p in parts:
        assert p in nodes
    # every non-root node has exactly one parent (single next hop)
    assert set(tree.parent) == nodes - {root}
    # neighbours only (mesh links)
    for child, par in tree.parent.items():
        assert abs(child[0] - par[0]) + abs(child[1] - par[1]) == 1
    # every leaf is a participant (trees are unions of participant routes)
    for leaf in tree.leaves():
        assert leaf in set(parts) | {root}


@pytest.mark.parametrize("name", PARTICIPANT_SETS, ids=str)
def test_segments_partition_tree_edges(name):
    parts = PARTICIPANT_SETS[name]
    tree = reduction_tree(sorted(parts)[0], parts)
    segs = segments(tree)
    edges = [(s[i], s[i + 1]) for s in segs for i in range(len(s) - 1)]
    assert len(edges) == len(set(edges)) == len(tree.parent)


def test_column_tree_is_the_paper_chain():
    """A single-column participant set degenerates to the WS gather chain."""
    tree = reduction_tree((2, 7), mesh_column(8, 2))
    segs = segments(tree)
    assert len(segs) == 1 and len(segs[0]) == 8


# --------------------------------------------------------------------------- #
# 2. Planner equivalence and conservation laws
# --------------------------------------------------------------------------- #
ALGOS = ["reduce_bcast", "rs_ag"]
SEMS = ["ina", "eject_inject"]


@pytest.mark.parametrize("semantics", SEMS)
@pytest.mark.parametrize("algorithm", ALGOS)
def test_allreduce_delivers_full_sum_everywhere(algorithm, semantics):
    parts = full_mesh(4)
    prog = plan_collective("allreduce", parts, 1024, CFG,
                           algorithm=algorithm, semantics=semantics)
    got = delivered_contribs(prog)
    chunks = {c for node in got for c in got[node]}
    assert chunks == ({0} if algorithm == "reduce_bcast"
                      else set(range(len(parts))))
    for p in parts:
        for c in chunks:
            assert got[p][c] == frozenset(parts), (p, c, algorithm, semantics)


@pytest.mark.parametrize("semantics", SEMS)
@pytest.mark.parametrize("name", ["full_mesh_4", "row", "subset"], ids=str)
def test_reduce_add_conservation(name, semantics):
    """Reducing P contributions always costs exactly (P-1) adds per word,
    wherever the adds happen (router INA blocks or PE ALUs)."""
    parts = PARTICIPANT_SETS[name]
    payload = 4096
    prog = plan_collective("reduce", parts, payload, CFG,
                           semantics=semantics)
    adds = program_reduce_words(prog) + program_pe_adds(prog)
    assert adds == (len(parts) - 1) * _words(payload)
    root = sorted(set(parts))[0]
    assert delivered_contribs(prog)[root][0] == frozenset(parts)


@pytest.mark.parametrize("algorithm", ALGOS)
def test_allreduce_adds_independent_of_algorithm(algorithm):
    parts = full_mesh(4)
    payload = 1024
    prog = plan_collective("allreduce", parts, payload, CFG,
                           algorithm=algorithm, semantics="ina")
    adds = program_reduce_words(prog) + program_pe_adds(prog)
    assert adds == (len(parts) - 1) * _words(payload)


@pytest.mark.parametrize("op", ["reduce", "broadcast", "allreduce"])
def test_ina_semantics_beat_eject_inject(op):
    """The paper's headline, generalised: in-network accumulation/forking
    beats bouncing through PEs for every tree collective."""
    parts = full_mesh(4)
    runs = {}
    for sem in SEMS:
        prog = plan_collective(op, parts, 1024, CFG, semantics=sem)
        runs[sem] = run_program(prog, CFG)
    assert runs["ina"].latency_cycles < runs["eject_inject"].latency_cycles
    assert runs["ina"].ledger.network_energy_pj(CFG) < \
        runs["eject_inject"].ledger.network_energy_pj(CFG)


def test_broadcast_reaches_every_participant():
    for sem in SEMS:
        parts = PARTICIPANT_SETS["subset"]
        root = parts[0]
        prog = plan_collective("broadcast", parts, 512, CFG, root=root,
                               semantics=sem)
        got = delivered_contribs(prog)
        for p in parts:
            if p != root:
                assert got[p][0] == frozenset({root}), (p, sem)


def test_gather_collects_every_result_once():
    parts = mesh_row(8, 0)
    for sem in SEMS:
        prog = plan_collective("gather", parts, 32, CFG, root=(0, 0),
                               semantics=sem)
        assert delivered_contribs(prog)[(0, 0)][0] == frozenset(parts)


def test_psum_mode_costs_match_link_traffic_theory():
    """Simulated mesh costs preserve the analytic ordering: in-network
    strategies move ~(P-1)/P of the bytes the relay ring moves, so the
    eject/inject latency must dominate at every size."""
    for nbytes in (1 << 10, 1 << 18):
        costs = psum_mode_costs(8, nbytes)
        assert costs["eject_inject"].latency_cycles > \
            costs["ina"].latency_cycles
        assert costs["eject_inject"].latency_cycles > \
            costs["ina_ring"].latency_cycles
        assert costs["eject_inject"].energy_pj > costs["ina"].energy_pj


# --------------------------------------------------------------------------- #
# 3. WS+INA regression through the planner (seed numbers, exact)
# --------------------------------------------------------------------------- #
SEED_IMPROVEMENTS = {
    # (latency_x, power_x, energy_x) at e_pes=1, sim_rounds=16, default cfg —
    # captured from the pre-refactor traffic generator.
    "alexnet": (1.3174422192115254, 1.5607175433789333, 2.056155183911502),
    "vgg16": (1.7419385086187669, 1.1141116323217497, 1.9407139552413686),
    "resnet50": (1.1205548873901459, 1.095398960338809, 1.227454658649737),
}


@pytest.mark.parametrize("workload", sorted(SEED_IMPROVEMENTS), ids=str)
def test_ws_ina_regression_through_planner(workload):
    imp = ws_ina_improvement(workload, WORKLOADS[workload], 1, CFG,
                             sim_rounds=16)
    lat, pwr, en = SEED_IMPROVEMENTS[workload]
    assert imp.latency_x == pytest.approx(lat, rel=1e-9)
    assert imp.power_x == pytest.approx(pwr, rel=1e-9)
    assert imp.energy_x == pytest.approx(en, rel=1e-9)


def test_ws_noina_seed_latency_energy_exact():
    """Raw pinned numbers for the contended baseline window (the hardest
    case for schedule-order fidelity: relay chains gate the gather)."""
    from repro.core.noc import simulate_network
    r = simulate_network(ALEXNET, "ws_noina", CFG, 1, 16)
    assert r["latency_cycles"] == pytest.approx(98214.0)
    assert r["total_energy_pj"] == pytest.approx(34766892.55)
    r = simulate_network(ALEXNET, "ws_ina", CFG, 1, 16)
    assert r["latency_cycles"] == pytest.approx(74549.0)
    assert r["total_energy_pj"] == pytest.approx(16908690.95)
