"""Per-architecture smoke tests: reduced config, forward + train-grad +
decode step on CPU; asserts shapes and finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.api import get_model, param_specs

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, model, b=2, s=32):
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(key, (b, s), 0, cfg.vocab),
    }
    if cfg.family in ("encdec", "vlm") and cfg.num_media_tokens:
        batch["media"] = jax.random.normal(
            key, (b, cfg.num_media_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def built():
    """Init each reduced arch once per test module."""
    cache = {}

    def build(arch):
        if arch not in cache:
            cfg = ARCHS[arch].reduced()
            model = get_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return build


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, built):
    cfg, model, params = built(arch)
    batch = _batch(cfg, model)
    logits = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch} produced non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_grads_finite(arch, built):
    cfg, model, params = built(arch)
    batch = _batch(cfg, model)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: model.loss(p, batch)))(params)
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    flat = jax.tree.leaves(grads)
    assert flat and all(bool(jnp.isfinite(g).all()) for g in flat), \
        f"{arch} has non-finite grads"
    # loss should start near ln(vocab) for random init
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab) + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch, built):
    cfg, model, params = built(arch)
    b, s = 2, 32
    cache = model.init_cache(b, s)
    batch = {"tokens": jnp.zeros((b, 1), jnp.int32),
             "pos": jnp.asarray(s - 1, jnp.int32)}
    if cfg.family in ("encdec", "vlm") and cfg.num_media_tokens:
        batch["media"] = jnp.ones((b, cfg.num_media_tokens, cfg.d_model),
                                  jnp.float32)
    if cfg.family == "vlm":
        from repro.models import vision
        cache = vision.prefill_media_kv(params, cfg, batch["media"], cache)
    logits, new_cache = jax.jit(
        lambda p, bt, c: model.decode_step(p, bt, c))(params, batch, cache)
    assert logits.shape == (b, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch} decode non-finite"
    # cache must be structurally unchanged
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_tree(arch, built):
    from jax.sharding import PartitionSpec as P
    cfg, model, params = built(arch)
    specs = param_specs(params)
    spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    param_leaves = jax.tree.leaves(params)
    assert len(spec_leaves) == len(param_leaves)
    assert all(isinstance(s, P) for s in spec_leaves)
    # ranks must match so the specs are usable as NamedShardings
    for s, p in zip(spec_leaves, param_leaves):
        assert len(s) <= p.ndim, (s, p.shape)


def test_all_archs_registered():
    assert len(ARCHS) == 10
    fams = {c.family for c in ARCHS.values()}
    assert fams == {"dense", "moe", "mla_moe", "ssm", "hybrid", "encdec",
                    "vlm"}
