"""PR-4 performance layer: compiled programs, route memo, persistent cache,
process-pool fan-out.

Contracts under test (DESIGN.md S10):

1. Route memoization returns exactly the unmemoized paths, and repeated
   ``enqueue`` of the same (src, dst) never re-derives a route.
2. Compiled flat-array replay is bit-identical (latency *and* full
   EnergyLedger) to the closure-based heap engine across every fig7-12
   plan shape, and round replication equals whole-window compilation.
3. ``--jobs N`` changes wall-clock only: mapper rows, Pareto fronts, and
   best schedules are identical for jobs=1 and jobs=4.
4. The persistent window cache round-trips bit-identically, is invisible
   when the schema hash or the NocConfig changes, and merges atomically.
"""
import dataclasses
import json

import pytest

from repro.core.noc import (EnergyLedger, NocConfig, NocSim, SIM_CACHE,
                            compile_program, compiled_disabled,
                            fresh_sim_cache, sim_cache_disabled)
from repro.core.noc import simcache, topology
from repro.core.noc.collective.engine import run_program
from repro.core.noc.collective.schedule import (plan_collective,
                                                ws_round_program)
from repro.core.noc.simcache import SimCache, schema_hash
from repro.core.noc.topology import (ROUTE_STATS, links_of, route_links,
                                     xy_route, xy_route_uncached)
from repro.core.noc.traffic import MODES, _plan, simulate_layer
from repro.core.workloads import ALEXNET, RESNET50, VGG16, WORKLOADS
from repro.exec import parallel_map

CFG = NocConfig()


# --------------------------------------------------------------------------- #
# 1. Route memoization
# --------------------------------------------------------------------------- #
def test_route_memo_identical_over_full_mesh():
    """Cached xy_route/links_of match the unmemoized derivation for every
    (src, dst) pair of the paper's 8x8 mesh."""
    nodes = [(x, y) for x in range(8) for y in range(8)]
    for src in nodes:
        for dst in nodes:
            truth = xy_route_uncached(src, dst)
            assert xy_route(src, dst) == truth
            assert list(route_links(src, dst)) == links_of(truth)


def test_exotic_and_flat_packets_contend_on_shared_links():
    """Per-link encoding: a packet with an out-of-mesh hop still reserves
    its in-mesh links in the shared flat arrays, so it serializes against
    ordinary packets on the same physical link (regression: per-packet
    overflow fallback used to split the contention domains)."""
    done = {}
    sim = NocSim(CFG)
    # A's path leaves the 8x8 mesh on its last hop; B shares (1,0)->(2,0).
    # Distinct VCs keep the injection ports distinct, so any delay B sees
    # can only come from the shared physical link.
    sim.enqueue(0, (1, 0), (9, 0), 4, vc=1,
                path=[(1, 0), (2, 0), (9, 0)],
                on_done=lambda t: done.setdefault("a", t))
    sim.enqueue(0, (1, 0), (3, 0), 4, vc=0,
                on_done=lambda t: done.setdefault("b", t))
    sim.run()
    solo = NocSim(CFG)
    solo.enqueue(0, (1, 0), (3, 0), 4, vc=0,
                 on_done=lambda t: done.setdefault("b_solo", t))
    solo.run()
    assert done["b"] > done["b_solo"]            # contention was modeled


def test_repeated_enqueue_does_not_rederive_route():
    sim = NocSim(CFG)
    sim.enqueue(0, (3, 1), (3, 6), 4)          # may derive (cold cache)
    before = ROUTE_STATS["derived"]
    for t in range(1, 6):
        sim.enqueue(t, (3, 1), (3, 6), 4)      # must all be memo hits
    assert ROUTE_STATS["derived"] == before
    sim2 = NocSim(CFG)                          # fresh sim, same process memo
    sim2.enqueue(0, (3, 1), (3, 6), 4)
    assert ROUTE_STATS["derived"] == before


# --------------------------------------------------------------------------- #
# 2. Compiled replay == heap engine, bit for bit
# --------------------------------------------------------------------------- #
def _fig_plan_shapes():
    """Distinct (cfg, mode, g, p, gather_flits, unicast_flits, e) shapes of
    the full figs 7-12 evaluation (3 workloads x E in {1,2,4,8} x 3 modes)."""
    shapes = {}
    for layers in (ALEXNET, VGG16, RESNET50):
        for layer in layers:
            for mode in MODES:
                for e in (1, 2, 4, 8):
                    plan = _plan(layer, CFG, e, mode)
                    key = (mode, plan.g, plan.p, plan.gather_flits,
                           plan.unicast_flits, e)
                    shapes.setdefault(key, (plan, mode, e))
    return list(shapes.values())


def _ledger_dict(ledger):
    return dataclasses.asdict(ledger)


def test_compiled_window_bit_identical_to_heap_on_fig_shapes():
    shapes = _fig_plan_shapes()
    assert len(shapes) > 10                      # the sweep is non-trivial
    for plan, mode, e in shapes:
        prog = ws_round_program(CFG, mode, 4, g=plan.g, p=plan.p,
                                gather_flits=plan.gather_flits,
                                unicast_flits=plan.unicast_flits, e_pes=e)
        heap = run_program(prog, CFG, engine="heap")
        latency, ledger, done, _ = compile_program(prog, CFG).run()
        assert latency == heap.latency_cycles, (mode, e)
        assert done == heap.done, (mode, e)
        assert _ledger_dict(ledger) == _ledger_dict(heap.ledger), (mode, e)


def test_replicated_round_equals_whole_window_compile():
    for mode in MODES:
        plan = _plan(ALEXNET[3], CFG, 2, mode)
        kw = dict(g=plan.g, p=plan.p, gather_flits=plan.gather_flits,
                  unicast_flits=plan.unicast_flits, e_pes=2)
        whole = compile_program(ws_round_program(CFG, mode, 6, **kw), CFG)
        tiled = compile_program(ws_round_program(CFG, mode, 1, **kw),
                                CFG).replicate(6)
        lat_w, led_w, done_w, _ = whole.run()
        lat_t, led_t, done_t, _ = tiled.run()
        assert (lat_w, done_w) == (lat_t, done_t)
        assert _ledger_dict(led_w) == _ledger_dict(led_t)


@pytest.mark.parametrize("op,algorithm", [
    ("reduce", "reduce_bcast"), ("broadcast", "reduce_bcast"),
    ("gather", "reduce_bcast"), ("allreduce", "reduce_bcast"),
    ("allreduce", "rs_ag")])
@pytest.mark.parametrize("semantics", ["ina", "eject_inject"])
def test_engine_auto_matches_heap_for_collectives(op, algorithm, semantics):
    """run_program's compiled dispatch is invisible for tree collectives
    (multicast drops, path overrides, virtual ops included)."""
    parts = [(x, y) for x in range(4) for y in range(4) if (x + y) % 2 == 0]
    prog = plan_collective(op, parts, 512, CFG, algorithm=algorithm,
                           semantics=semantics)
    heap = run_program(prog, CFG, engine="heap")
    auto = run_program(prog, CFG, engine="auto")
    assert auto.latency_cycles == heap.latency_cycles
    assert auto.done == heap.done
    assert auto.delivered == heap.delivered
    assert _ledger_dict(auto.ledger) == _ledger_dict(heap.ledger)


@pytest.mark.parametrize("mode", MODES)
def test_simulate_layer_identical_under_all_execution_modes(mode):
    """Ground truth (heap, no caches) == compiled cold == compiled warm."""
    layer = VGG16[8]
    with fresh_sim_cache(), compiled_disabled(), sim_cache_disabled():
        truth = simulate_layer(layer, mode, CFG, 2, sim_rounds=8)
    with fresh_sim_cache():
        cold = simulate_layer(layer, mode, CFG, 2, sim_rounds=8)
        warm = simulate_layer(layer, mode, CFG, 2, sim_rounds=8)
    for r in (cold, warm):
        assert dataclasses.asdict(r) == dataclasses.asdict(truth), mode


# --------------------------------------------------------------------------- #
# 3. --jobs N is observationally equivalent to --jobs 1
# --------------------------------------------------------------------------- #
def _search(jobs):
    from repro.core.workloads import mapper_workloads
    from repro.mapper import QUICK_MAPPER, search_network
    wl = mapper_workloads(conv=("alexnet",), transformers=())
    return search_network("alexnet", wl["alexnet"], QUICK_MAPPER, jobs=jobs)


def test_jobs_1_and_jobs_4_identical_mapper_output():
    with fresh_sim_cache():
        serial = _search(jobs=1)
    with fresh_sim_cache():
        fanned = _search(jobs=4)
    assert serial.best.to_dict() == fanned.best.to_dict()
    assert serial.baseline.to_dict() == fanned.baseline.to_dict()
    assert [s.to_dict() for s in serial.pareto] \
        == [s.to_dict() for s in fanned.pareto]
    assert (serial.latency_x, serial.energy_x) \
        == (fanned.latency_x, fanned.energy_x)
    # Work accounting (not cache hit/miss split) is jobs-invariant too.
    for k in ("candidates", "simulated", "hardware_evaluated"):
        assert serial.stats[k] == fanned.stats[k]


def _simulate_one(args):
    layer_idx, e = args
    r = simulate_layer(ALEXNET[layer_idx], "ws_ina", CFG, e, sim_rounds=4)
    return r.latency_cycles


def test_parallel_map_merges_worker_cache_entries():
    with fresh_sim_cache():
        before = len(SIM_CACHE)
        out = parallel_map(_simulate_one, [(1, 1), (2, 2), (3, 4), (4, 8)],
                           jobs=2)
        assert len(out) == 4
        assert len(SIM_CACHE) > before           # worker deltas merged back
        with sim_cache_disabled(), compiled_disabled():
            truth = [_simulate_one(a) for a in [(1, 1), (2, 2), (3, 4),
                                                (4, 8)]]
        assert out == truth


# --------------------------------------------------------------------------- #
# 4. Persistent on-disk cache
# --------------------------------------------------------------------------- #
def _window_key(cfg=CFG, window=4):
    plan = _plan(ALEXNET[3], cfg, 1, "ws_ina")
    return (cfg, "ws_ina", window, plan.g, plan.p, plan.gather_flits,
            plan.unicast_flits, 1)


def test_persistent_cache_round_trips_bit_identically(tmp_path):
    writer = SimCache()
    key = _window_key()
    ledger = EnergyLedger(flit_routers=12, ni_flits=3.25, pe_adds=7)
    writer.put(key, 123.0, ledger)
    assert writer.save(tmp_path) == 1

    reader = SimCache()
    assert reader.load(tmp_path) == 1
    hit = reader.get(key)
    assert hit is not None
    lat, led = hit
    assert lat == 123.0
    assert dataclasses.asdict(led) == dataclasses.asdict(ledger)
    assert reader.stats()["disk_hits"] == 1
    # A different NocConfig is a different key: nothing stale is served.
    other = reader.get(_window_key(dataclasses.replace(CFG, n=4)))
    assert other is None


def test_persistent_cache_invisible_on_schema_change(tmp_path, monkeypatch):
    writer = SimCache()
    writer.put(_window_key(), 7.0, EnergyLedger())
    writer.save(tmp_path)
    monkeypatch.setattr(simcache, "SCHEMA_VERSION", simcache.SCHEMA_VERSION + 1)
    reader = SimCache()
    assert reader.load(tmp_path) == 0            # cold start, not an error
    assert reader.get(_window_key()) is None


def test_persistent_cache_save_merges_concurrent_writers(tmp_path):
    a, b = SimCache(), SimCache()
    ka, kb = _window_key(window=3), _window_key(window=5)
    a.put(ka, 1.0, EnergyLedger(flit_links=1))
    b.put(kb, 2.0, EnergyLedger(flit_links=2))
    a.save(tmp_path)
    b.save(tmp_path)                             # must union, not clobber
    reader = SimCache()
    assert reader.load(tmp_path) == 2
    assert reader.get(ka)[0] == 1.0
    assert reader.get(kb)[0] == 2.0


def test_persistent_cache_warms_simulation_across_instances(tmp_path):
    layer = ALEXNET[2]
    with fresh_sim_cache():
        first = simulate_layer(layer, "ws_ina", CFG, 1, sim_rounds=6)
        assert SIM_CACHE.save(tmp_path) > 0
    with fresh_sim_cache():
        assert SIM_CACHE.load(tmp_path) > 0
        again = simulate_layer(layer, "ws_ina", CFG, 1, sim_rounds=6)
        stats = SIM_CACHE.stats()
        assert stats["misses"] == 0              # fully served from disk
        assert stats["disk_hits"] > 0
    assert dataclasses.asdict(again) == dataclasses.asdict(first)


def test_schema_hash_tracks_config_and_ledger_fields():
    h = schema_hash()
    assert isinstance(h, str) and len(h) == 16
    assert h == schema_hash()                    # stable within a process


def test_cache_file_is_json_with_schema(tmp_path):
    c = SimCache()
    c.put(_window_key(), 9.0, EnergyLedger())
    c.save(tmp_path)
    doc = json.loads((tmp_path / "window_cache.json").read_text())
    assert doc["schema"] == schema_hash()
    assert len(doc["entries"]) == 1


# --------------------------------------------------------------------------- #
# 5. Ledger copy + hit-rate stats (satellite 1)
# --------------------------------------------------------------------------- #
def test_energy_ledger_copy_is_cheap_and_isolated():
    a = EnergyLedger(flit_routers=5, ni_flits=2.5, stream_flit_segments=7)
    b = a.copy()
    assert dataclasses.asdict(a) == dataclasses.asdict(b)
    b.ni_flits += 100
    assert a.ni_flits == 2.5                     # no aliasing
    assert EnergyLedger.from_tuple(a.as_tuple()) == a


def test_simcache_reports_hit_rate():
    c = SimCache()
    c.put("k", 1.0, EnergyLedger())
    assert c.get("k") is not None
    assert c.get("missing") is None
    s = c.stats()
    assert s["hits"] == 1 and s["misses"] == 1
    assert s["hit_rate"] == 0.5


def test_collective_cost_stays_hashable_with_ledger():
    """The per-event ledger ships with CollectiveCost but is excluded from
    eq/hash (regression: a mutable compare field made instances
    unhashable)."""
    from repro.core.noc.collective.cost import collective_cost
    cost = collective_cost("reduce", 128.0, dataclasses.replace(CFG, n=4))
    assert cost.ledger is not None
    assert cost in {cost}                        # hashable, set-usable
    assert cost == dataclasses.replace(cost, ledger=None)  # ledger not compared


def test_cache_hands_out_independent_ledger_copies():
    c = SimCache()
    c.put("k", 1.0, EnergyLedger(pe_adds=1))
    _, l1 = c.get("k")
    l1.pe_adds += 99
    _, l2 = c.get("k")
    assert l2.pe_adds == 1                       # the stored copy is private
