"""ExecutionPlan layer (DESIGN.md S11): determinism, persistence,
planless-fallback equivalence, and the collective-simulation counters.

Coverage map (ISSUE 5):

* plan JSON is byte-deterministic; the store round-trips plans and a
  schema-hash mismatch invalidates (rebuild, never stale reads);
* plan-driven ``psum_with_mode`` is numerically identical to the planless
  ``mode="auto"`` path (resolution-level equality here, device-level
  equality in the slow 8-device subprocess test);
* one site shape costs one simulation set per trace, rides the persistent
  sim cache (``COST_STATS`` deltas — the ROUTE_STATS-style regression),
  and the ``xla``/``ina`` lowering alias + auto candidate set are pinned;
* every registry config plans the decode phase (the ``--section plan``
  smoke unit).
"""
import json
import os
import subprocess
import sys

import pytest

from repro.configs import ARCHS
from repro.core.noc.collective.cost import (AUTO_CANDIDATES, COST_STATS,
                                            PSUM_MODE_LOWERING, _simulate,
                                            choose_psum_mode, psum_mode_costs)
from repro.core.noc.simcache import SIM_CACHE, fresh_sim_cache
from repro.plan import (ExecutionPlan, PlanStore, PsumDecision, build_plan,
                        choose_tiles, plan_schema_hash)

MESH = (("data", 16), ("model", 16))


def _decode_plan(arch="qwen2-1.5b", **kw):
    kw.setdefault("gemm_search", False)
    return build_plan(ARCHS[arch], MESH, "decode", **kw)


# --------------------------------------------------------------------------- #
# 1. Determinism + persistence
# --------------------------------------------------------------------------- #
def test_plan_json_byte_deterministic():
    a = build_plan(ARCHS["qwen2-1.5b"], MESH, "decode", gemm_search=True,
                   mapper_space="quick")
    b = build_plan(ARCHS["qwen2-1.5b"], MESH, "decode", gemm_search=True,
                   mapper_space="quick")
    assert a.to_json() == b.to_json()
    assert a == b and hash(a) == hash(b)


def test_plan_store_roundtrip(tmp_path):
    plan = _decode_plan()
    store = PlanStore(tmp_path)
    path = store.save(plan)
    assert path.name == f"{plan.key}.json"
    loaded = store.load(plan.key)
    assert loaded == plan
    assert loaded.to_json() == plan.to_json()
    # lookups survive the round trip
    d = plan.psum[0]
    assert loaded.psum_mode(d.p, d.nbytes) == d.mode


def test_plan_store_schema_invalidation(tmp_path):
    plan = _decode_plan()
    store = PlanStore(tmp_path)
    path = store.save(plan)
    doc = json.loads(path.read_text())
    doc["schema"] = "stale0000stale00"
    path.write_text(json.dumps(doc))
    assert store.load(plan.key) is None
    # get_or_build treats the stale file as cold and rebuilds in place
    rebuilt, built = store.get_or_build(ARCHS["qwen2-1.5b"], MESH, "decode",
                                        gemm_search=False)
    assert built and rebuilt.schema == plan_schema_hash()
    assert store.load(plan.key) == rebuilt


def test_plan_store_corrupt_file_is_cold(tmp_path):
    store = PlanStore(tmp_path)
    plan = _decode_plan()
    store.save(plan)
    store.path_for(plan.key).write_text("{not json")
    assert store.load(plan.key) is None


def test_store_rebuilds_on_build_param_mismatch(tmp_path):
    """The key covers (model, mesh, phase, dtype) only; explicit build
    parameters are checked against the stored plan — a quick-space store
    must never answer a full-space request as warm."""
    store = PlanStore(tmp_path)
    p1, built1 = store.get_or_build(ARCHS["qwen2-1.5b"], MESH, "decode",
                                    gemm_search=True, mapper_space="quick")
    assert built1 and p1.mapper_space == "quick"
    p2, built2 = store.get_or_build(ARCHS["qwen2-1.5b"], MESH, "decode",
                                    gemm_search=True, mapper_space="full")
    assert built2 and p2.mapper_space == "full"       # mismatch = rebuild
    _, built3 = store.get_or_build(ARCHS["qwen2-1.5b"], MESH, "decode",
                                   gemm_search=True, mapper_space="full")
    assert not built3                                 # now genuinely warm
    # a gemm-less plan cannot satisfy a caller that wants verdicts
    _, built4 = store.get_or_build(ARCHS["qwen2-1.5b"], MESH, "decode",
                                   gemm_search=False)
    assert not built4                # superset plan serves the plain request
    store2 = PlanStore(tmp_path / "bare")
    store2.get_or_build(ARCHS["qwen2-1.5b"], MESH, "decode",
                        gemm_search=False)
    _, rebuilt = store2.get_or_build(ARCHS["qwen2-1.5b"], MESH, "decode",
                                     gemm_search=True, mapper_space="quick")
    assert rebuilt


def test_store_rebuilds_on_config_edit(tmp_path):
    """A registry-config edit keeps the name/dtype (same key) but must go
    cold — the plan records a config-content digest."""
    import dataclasses
    store = PlanStore(tmp_path)
    cfg = ARCHS["qwen2-1.5b"]
    p1, _ = store.get_or_build(cfg, MESH, "decode", gemm_search=False)
    cfg2 = dataclasses.replace(cfg, d_ff=cfg.d_ff * 2)
    p2, built = store.get_or_build(cfg2, MESH, "decode", gemm_search=False)
    assert built and p2.config != p1.config
    assert p2.key == p1.key                   # same file, new content


def test_plan_miss_fallback_honors_plan_objective():
    from repro.core.collectives import resolve_auto_mode
    p, nbytes = 9, 77_777                     # unique; plan never saw it
    plan = ExecutionPlan(model="t", mesh=(("model", p),), phase="decode",
                         dtype="float32", objective="energy")
    assert resolve_auto_mode("psum", p, nbytes, plan) \
        == choose_psum_mode(p, nbytes, objective="energy")


def test_gemm_verdicts_memoized_across_phases():
    from repro.plan.builder import _GEMM_MEMO, gemm_verdicts
    cfg = ARCHS["qwen2-1.5b"]
    first = gemm_verdicts(cfg, 256, "quick")
    assert (cfg, 256, "quick") in _GEMM_MEMO
    assert gemm_verdicts(cfg, 256, "quick") is first   # shared, not re-run


def test_launch_phase_distinguishes_cli_shapes():
    from repro.configs.base import SHAPES, ShapeConfig
    from repro.plan import launch_phase
    a = ShapeConfig("cli", 16, 2, "decode")
    b = ShapeConfig("cli", 512, 32, "decode")
    assert launch_phase(a) != launch_phase(b)         # no plan-file collision
    assert launch_phase(SHAPES["decode_32k"]) == "decode"
    assert launch_phase(SHAPES["train_4k"]) == "train"
    assert launch_phase(SHAPES["long_500k"]) not in ("decode", "long_500k")


def test_get_or_build_warm_store_zero_sims(tmp_path):
    store = PlanStore(tmp_path)
    plan, built = store.get_or_build(ARCHS["qwen2-1.5b"], MESH, "decode",
                                     gemm_search=False)
    assert built
    runs0 = COST_STATS["engine_runs"]
    again, built2 = store.get_or_build(ARCHS["qwen2-1.5b"], MESH, "decode",
                                       gemm_search=False)
    assert not built2 and again == plan
    assert COST_STATS["engine_runs"] == runs0     # warm: zero simulations


# --------------------------------------------------------------------------- #
# 2. Planless-fallback equivalence (resolution level)
# --------------------------------------------------------------------------- #
def test_plan_decisions_match_planless_auto():
    """Every planned strategy equals what today's per-call-site auto path
    would pick — the mechanism behind bit-identical plan-driven steps."""
    plan = _decode_plan("llama3-8b")
    assert plan.psum, "decode trace found no auto psum sites"
    for d in plan.psum:
        assert plan.psum_mode(d.p, d.nbytes) == d.mode
        assert d.mode == choose_psum_mode(d.p, d.nbytes)
        assert d.mode in AUTO_CANDIDATES
    # unplanned site shapes miss (callers fall back, never error)
    assert plan.psum_mode(3, 999) is None


def test_resolve_auto_mode_regimes():
    from repro.core.collectives import record_psum_sites, resolve_auto_mode
    p, nbytes = 16, 1 << 20
    # recording: sites captured, nothing simulated
    runs0 = COST_STATS["engine_runs"] + COST_STATS["store_hits"]
    with record_psum_sites() as sites:
        stand_in = resolve_auto_mode("psum", p, nbytes)
    assert stand_in == "ina"
    assert [(s.op, s.p, s.nbytes) for s in sites] == [("psum", p, nbytes)]
    assert COST_STATS["engine_runs"] + COST_STATS["store_hits"] == runs0
    # plan-driven: the plan's answer wins
    plan = ExecutionPlan(model="t", mesh=(("model", p),), phase="decode",
                         dtype="float32",
                         psum=(PsumDecision(p=p, nbytes=nbytes,
                                            mode="eject_inject",
                                            ops=("psum",), count=1),))
    assert resolve_auto_mode("psum", p, nbytes, plan) == "eject_inject"
    # plan miss: trace-time fallback
    assert resolve_auto_mode("psum", p, 12345, plan) \
        == choose_psum_mode(p, 12345)


# --------------------------------------------------------------------------- #
# 3. Simulation counters (satellite: one sim set per site shape per trace,
#    persistent across processes via the window store)
# --------------------------------------------------------------------------- #
def test_auto_resolution_simulates_each_shape_once():
    p, nbytes = 6, 54_321                      # unique to this test
    with fresh_sim_cache():
        _simulate.cache_clear()
        runs0 = COST_STATS["engine_runs"]
        choose_psum_mode(p, nbytes)
        delta = COST_STATS["engine_runs"] - runs0
        # 4 modes, 3 distinct lowerings (xla aliases ina) -> 3 engine runs
        assert delta == 3
        choose_psum_mode(p, nbytes)
        psum_mode_costs(p, nbytes)
        assert COST_STATS["engine_runs"] - runs0 == 3    # memoized


def test_collective_sims_ride_persistent_store(tmp_path):
    p, nbytes = 7, 98_765                      # unique to this test
    with fresh_sim_cache():
        _simulate.cache_clear()
        choose_psum_mode(p, nbytes)
        SIM_CACHE.save(tmp_path)
    with fresh_sim_cache():
        _simulate.cache_clear()
        loaded = SIM_CACHE.load(tmp_path)
        assert loaded > 0
        runs0 = COST_STATS["engine_runs"]
        hits0 = COST_STATS["store_hits"]
        mode = choose_psum_mode(p, nbytes)
        assert COST_STATS["engine_runs"] == runs0        # zero engine runs
        assert COST_STATS["store_hits"] - hits0 == 3
    with fresh_sim_cache():
        _simulate.cache_clear()
        assert choose_psum_mode(p, nbytes) == mode       # ground truth agrees


def test_store_hit_costs_bit_identical(tmp_path):
    """Costs served from the persistent store equal engine ground truth."""
    from repro.core.noc.collective.cost import collective_cost
    kw = dict(payload_bits=4096.0)
    with fresh_sim_cache():
        _simulate.cache_clear()
        truth = collective_cost("allreduce", **kw)
        SIM_CACHE.save(tmp_path)
    with fresh_sim_cache():
        _simulate.cache_clear()
        SIM_CACHE.load(tmp_path)
        warm = collective_cost("allreduce", **kw)
    assert warm == truth
    assert warm.ledger.as_tuple() == truth.ledger.as_tuple()


# --------------------------------------------------------------------------- #
# 4. The xla/ina lowering alias + auto candidate set (satellite pin)
# --------------------------------------------------------------------------- #
def test_auto_candidate_set_pinned():
    assert AUTO_CANDIDATES == ("ina", "ina_ring", "eject_inject")
    assert "xla" not in AUTO_CANDIDATES
    # the alias auto's exclusion rests on: xla lowers exactly like ina
    assert PSUM_MODE_LOWERING["xla"] == PSUM_MODE_LOWERING["ina"] \
        == ("reduce_bcast", "ina")
    assert set(PSUM_MODE_LOWERING) == {"ina", "ina_ring", "eject_inject",
                                       "xla"}
    costs = psum_mode_costs(8, 2048)
    assert costs["xla"].latency_cycles == costs["ina"].latency_cycles
    assert costs["xla"].energy_pj == costs["ina"].energy_pj


# --------------------------------------------------------------------------- #
# 5. Tiles
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("m,k,n", [(256, 4096, 1024), (128, 14336, 4096),
                                   (1, 4096, 1000), (384, 768, 96)])
def test_choose_tiles_divide_and_fit(m, k, n):
    for dtype in ("float32", "bfloat16"):
        bm, bn, bk = choose_tiles(m, k, n, dtype)
        assert m % bm == 0 and n % bn == 0 and k % bk == 0
        import jax.numpy as jnp
        item = jnp.dtype(dtype).itemsize
        ws = (bm * bk + bk * bn) * item * 2 + bm * bn * (4 + item)
        from repro.plan.tiles import VMEM_BUDGET_BYTES
        assert ws <= VMEM_BUDGET_BYTES


def test_plan_tiles_drive_ina_matmul():
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import matmul
    plan = _decode_plan("qwen2-1.5b")
    t = plan.tiles[0]
    assert plan.tile_for(t.m, t.k, t.n, t.dtype) == t.tiles
    assert t.m % t.bm == 0 and t.n % t.bn == 0 and t.k % t.bk == 0
    # planned tiles produce the same numbers as the default blocks
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32)
    tiny = ExecutionPlan(
        model="t", mesh=(("model", 1),), phase="decode", dtype="float32",
        tiles=(type(t)(m=64, k=256, n=128, dtype="float32",
                       bm=32, bn=64, bk=128),))
    got = matmul(x, w, interpret=True, plan=tiny)
    ref = matmul(x, w, interpret=True)
    assert jnp.allclose(got, ref, atol=1e-5)


# --------------------------------------------------------------------------- #
# 6. Per-config smoke: all registry configs plan the decode phase
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_all_configs_plan_decode(arch):
    plan = _decode_plan(arch)
    assert plan.model == arch and plan.phase == "decode"
    assert plan.schema == plan_schema_hash()
    assert plan.psum, f"{arch}: no auto psum sites traced"
    for d in plan.psum:
        assert d.mode in AUTO_CANDIDATES and d.count >= 1
        assert len(d.costs) == len(AUTO_CANDIDATES)
    assert plan.tiles
    s = plan.psum_summary()
    assert s["sites"] >= s["distinct"] >= 1
    assert s["latency_delta_x"] >= 1.0     # never worse than all-eject/inject
    # round-trips through JSON
    assert ExecutionPlan.from_json(plan.to_json()) == plan


def test_three_phases_distinct_keys():
    keys = set()
    for phase in ("train", "prefill", "decode"):
        plan = build_plan(ARCHS["qwen2-1.5b"], MESH, phase,
                          gemm_search=False)
        assert plan.phase == phase and plan.psum
        keys.add(plan.key)
    assert len(keys) == 3


# --------------------------------------------------------------------------- #
# 7. The --section plan sweep, its CSV/markdown emitters, and the launch
#    helper (the surfaces the CI plan-smoke job rides)
# --------------------------------------------------------------------------- #
def test_run_plan_section_cold_then_warm(tmp_path):
    import dataclasses
    from repro.experiments.sweeps import QUICK_SWEEP, _plan_csv, run_plan
    sweep = dataclasses.replace(QUICK_SWEEP, plan_dir=str(tmp_path))
    fig = run_plan(sweep)
    assert len(fig["rows"]) == len(ARCHS)
    assert not any("plan_error" in r for r in fig["rows"])
    assert set(fig["plans"]) == {r["key"] for r in fig["rows"]}
    warm = run_plan(sweep)
    assert all(r["warm"] and r["collective_engine_runs"] == 0
               for r in warm["rows"])
    lines = _plan_csv(fig)
    assert all(l.startswith("plan_") for l in lines)
    assert all("\n" not in l and l.count(",") == 2 for l in lines)


def test_plan_error_rows_stay_parseable():
    from repro.experiments.report import _plan_table
    from repro.experiments.sweeps import _plan_csv
    rows = [{"workload": "x", "phase": "decode",
             "plan_error": "Boom, with, commas\nand | pipes",
             "elapsed_us": 1.0}]
    (line,) = _plan_csv({"rows": rows})
    assert line.startswith("plan_error_x_decode,")     # the CI grep prefix
    assert "\n" not in line and line.count(",") == 2
    table = _plan_table(rows)
    assert "|" == table.splitlines()[-1][0]            # one well-formed row
    assert len(table.splitlines()) == 3                # head + rule + row


def test_plan_for_launch_warm_roundtrip(tmp_path, monkeypatch):
    from repro.configs.base import SHAPES
    from repro.plan import plan_for_launch
    # Keep the helper's window-store wiring inside the sandbox: with a
    # persist dir already set it must not retarget to results/.simcache.
    monkeypatch.setattr(SIM_CACHE, "_persist_dir", tmp_path)
    cfg = ARCHS["qwen2-1.5b"]
    shape = SHAPES["decode_32k"]
    assert plan_for_launch(cfg, MESH, shape, "ina") == (None, None)
    plan, info = plan_for_launch(cfg, MESH, shape, "auto",
                                 plan_dir=tmp_path, verbose=False,
                                 gemm_search=False)
    assert plan is not None and not info["from_store"]
    plan2, info2 = plan_for_launch(cfg, MESH, shape, "auto",
                                   plan_dir=tmp_path, verbose=False,
                                   gemm_search=False)
    assert plan2 == plan
    assert info2["from_store"] and info2["collective_sims"] == 0
    assert plan.phase == "decode"          # canonical shape -> bare phase


# --------------------------------------------------------------------------- #
# 8. Device-level equivalence: plan-driven == planless auto, and the plan
#    really drives the lowering (8 host devices, subprocess isolation)
# --------------------------------------------------------------------------- #
SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core.collectives import choose_psum_mode, psum_with_mode
from repro.plan import ExecutionPlan, PsumDecision

devs = jax.devices()
mesh = Mesh(np.array(devs), ("model",))
x = jax.random.normal(jax.random.PRNGKey(0), (8, 16, 32), jnp.float32)

def run(plan):
    f = shard_map(lambda xs: psum_with_mode(xs[0], "model", "auto",
                                            plan=plan)[None],
                  mesh=mesh, in_specs=P("model"), out_specs=P("model"))
    return jax.jit(f)(x)

nbytes = 16 * 32 * 4                      # the local partial inside the region
auto_mode = choose_psum_mode(8, nbytes)
plan = ExecutionPlan(model="t", mesh=(("model", 8),), phase="decode",
                     dtype="float32",
                     psum=(PsumDecision(p=8, nbytes=nbytes, mode=auto_mode,
                                        ops=("psum",), count=1),))
planless = run(None)
planned = run(plan)
assert np.array_equal(np.asarray(planless), np.asarray(planned)), \
    "plan-driven psum not bit-identical to planless auto"

# A plan forcing the Fig. 4(a) baseline must change the lowering (proof the
# plan is consulted) while staying numerically equivalent.
forced = ExecutionPlan(model="t", mesh=(("model", 8),), phase="decode",
                       dtype="float32",
                       psum=(PsumDecision(p=8, nbytes=nbytes,
                                          mode="eject_inject",
                                          ops=("psum",), count=1),))
f = shard_map(lambda xs: psum_with_mode(xs[0], "model", "auto",
                                        plan=forced)[None],
              mesh=mesh, in_specs=P("model"), out_specs=P("model"),
              check_vma=False)
txt = jax.jit(f).lower(x).as_text()
n_cp = txt.count("collective_permute") + txt.count("collective-permute")
assert n_cp >= 7, f"plan-forced eject_inject not in HLO ({n_cp} permutes)"
np.testing.assert_allclose(np.asarray(jax.jit(f)(x)), np.asarray(planless),
                           rtol=1e-4, atol=1e-4)
print("PLAN_EQUIV_OK")
"""


@pytest.mark.slow
def test_plan_driven_psum_bit_identical_on_8_devices():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "PLAN_EQUIV_OK" in proc.stdout
