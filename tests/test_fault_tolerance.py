"""Fault-tolerant runtime: step retry, straggler watch, resume cadence,
preemption double-signal semantics (DESIGN.md S15)."""
import signal

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.ckpt import CheckpointManager, latest_step
from repro.runtime.fault_tolerance import (FTConfig, PreemptionGuard,
                                           StragglerWatch, run_training)


def _counting_step(fail_at=None, fail_times=1, calls=None, failures=None):
    """A step_fn raising JaxRuntimeError ``fail_times`` times at step
    ``fail_at`` (transient device error), succeeding otherwise."""
    calls = calls if calls is not None else []
    failures = failures if failures is not None else []

    def step_fn(state, batch):
        step = int(state["step"])
        calls.append(step)
        if step == fail_at and failures.count(step) < fail_times:
            failures.append(step)
            raise jax.errors.JaxRuntimeError("injected transient fault")
        return {"step": state["step"] + 1}, {"loss": 0.0}

    return step_fn, calls, failures


# --------------------------------------------------------------------------- #
# retry
# --------------------------------------------------------------------------- #
def test_transient_fault_retried_in_place(tmp_path):
    step_fn, calls, failures = _counting_step(fail_at=2, fail_times=1)
    ft = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                  max_step_retries=2)
    state, last, _ = run_training(step_fn, {"step": jnp.asarray(0)},
                                  lambda s: {}, ft=ft, num_steps=4)
    assert int(state["step"]) == 4 and last == 4
    # step 2 ran twice (failed attempt + retry), every other step once
    assert calls == [0, 1, 2, 2, 3]
    assert failures == [2]


def test_persistent_fault_force_saves_then_raises(tmp_path):
    step_fn, calls, _ = _counting_step(fail_at=2, fail_times=99)
    ft = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                  max_step_retries=2)
    with pytest.raises(jax.errors.JaxRuntimeError):
        run_training(step_fn, {"step": jnp.asarray(0)}, lambda s: {},
                     ft=ft, num_steps=4)
    # all retries consumed: 1 + max_step_retries attempts at the bad step
    assert calls.count(2) == 3
    # the pre-raise force-save landed: last completed state (step 2) is
    # restorable, so a restart loses nothing
    assert latest_step(str(tmp_path)) == 2


# --------------------------------------------------------------------------- #
# straggler watch
# --------------------------------------------------------------------------- #
def test_straggler_watch_event_contents():
    w = StragglerWatch(factor=3.0)
    for step in range(5):                    # build the trailing median
        assert not w.observe(step, 1.0)
    assert w.observe(5, 10.0)                # 10x the median -> event
    assert not w.observe(6, 1.1)             # back to normal
    assert len(w.events) == 1
    step, seconds, median = w.events[0]
    assert step == 5 and seconds == 10.0 and median == 1.0


def test_straggler_callback_fires(tmp_path):
    # Make observed durations deterministic by monkeypatching the watch
    # through recorded wall times is overkill here: drive observe()
    # indirectly with a sleepless step and assert no spurious events.
    step_fn, _, _ = _counting_step()
    ft = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=100)
    events = []
    _, _, watch_events = run_training(
        step_fn, {"step": jnp.asarray(0)}, lambda s: {}, ft=ft,
        num_steps=8, on_straggler=lambda step, dt: events.append(step))
    assert events == [s for s, *_ in watch_events]


# --------------------------------------------------------------------------- #
# resume cadence
# --------------------------------------------------------------------------- #
def test_resume_restarts_at_checkpoint_step_plus_one(tmp_path):
    # seed the directory with a checkpoint at step 3
    mgr = CheckpointManager(str(tmp_path), every=1)
    mgr.maybe_save({"step": jnp.asarray(4)}, 3, force=True)
    step_fn, calls, _ = _counting_step()
    ft = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=100)
    state, last, _ = run_training(step_fn, {"step": jnp.asarray(0)},
                                  lambda s: {}, ft=ft, num_steps=6)
    # step 3 already completed (its state is the checkpoint): execution
    # resumes at 4, never re-running a completed step
    assert calls == [4, 5]
    assert int(state["step"]) == 6 and last == 6


# --------------------------------------------------------------------------- #
# preemption: first signal drains, second signal exits now
# --------------------------------------------------------------------------- #
def test_single_signal_finishes_step_and_checkpoints(tmp_path):
    calls = []

    def step_fn(state, batch):
        step = int(state["step"])
        calls.append(step)
        if step == 1:
            signal.raise_signal(signal.SIGINT)
        return {"step": state["step"] + 1}, {}

    ft = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=100)
    state, last, _ = run_training(step_fn, {"step": jnp.asarray(0)},
                                  lambda s: {}, ft=ft, num_steps=10)
    # the signalled step still completed, then the loop checkpointed and
    # left cleanly — no KeyboardInterrupt escapes
    assert calls == [0, 1]
    assert int(state["step"]) == 2
    assert latest_step(str(tmp_path)) == 1


def test_guard_restores_handlers_after_first_signal():
    before = signal.getsignal(signal.SIGINT)
    with PreemptionGuard() as g:
        assert signal.getsignal(signal.SIGINT) == g._handler
        signal.raise_signal(signal.SIGINT)   # absorbed, sets the flag
        assert g.requested
        # handlers already restored: a second signal acts immediately
        assert signal.getsignal(signal.SIGINT) == before
        with pytest.raises(KeyboardInterrupt):
            signal.raise_signal(signal.SIGINT)
    assert signal.getsignal(signal.SIGINT) == before


def test_double_signal_force_saves_and_raises(tmp_path):
    def step_fn(state, batch):
        step = int(state["step"])
        if step == 2:
            signal.raise_signal(signal.SIGINT)   # drain request
            signal.raise_signal(signal.SIGINT)   # exit NOW
        return {"step": state["step"] + 1}, {}

    ft = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=100)
    with pytest.raises(KeyboardInterrupt):
        run_training(step_fn, {"step": jnp.asarray(0)}, lambda s: {},
                     ft=ft, num_steps=10)
    # last *completed* state (after step 1) was force-saved on the way out
    assert latest_step(str(tmp_path)) == 2
    # handlers fully restored after the context exits
    assert signal.getsignal(signal.SIGINT) == signal.default_int_handler
