import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
import json, traceback
from benchmarks.perf_iterations import hillclimb_mesh, measure

mesh = hillclimb_mesh(tp=16, dp=4)
CELLS = {
  # paper-representative dense WS cell
  "llama3-8b:train_4k": ([
    ("baseline_xla",   {}, {"psum_mode": "xla_spmd"}, False),
    ("ina_xla",        {}, {"psum_mode": "ina"}, False),
    ("ina_bf16params", {"param_dtype": "bfloat16"}, {"psum_mode": "ina"}, False),
    ("ina_bf16_rsseq", {"param_dtype": "bfloat16"},
                       {"psum_mode": "ina", "rs_seq": True}, False),
    ("bf16_rsseq_ring", {"param_dtype": "bfloat16"},
                       {"psum_mode": "ina", "rs_seq": True,
                        "sp_entry": True}, False),
    ("paper_eject_inject_1L", {}, {"psum_mode": "eject_inject"}, True),
    ("paper_ina_ring_1L",     {}, {"psum_mode": "ina_ring"}, True),
  ]),
  # most collective-bound cell (MoE EP)
  "llama4-scout-17b-16e:train_4k": ([
    ("baseline_xla",   {}, {"psum_mode": "xla_spmd"}, False),
    ("ina_manual_ep",  {}, {"psum_mode": "ina"}, False),
    ("ina_bf16params", {"param_dtype": "bfloat16"}, {"psum_mode": "ina"}, False),
    ("ina_bf16_rsseq", {"param_dtype": "bfloat16"},
                       {"psum_mode": "ina", "rs_seq": True}, False),
    ("bf16_rsseq_cap1", {"param_dtype": "bfloat16",
                         "__moe__": {"capacity_factor": 1.0}},
                        {"psum_mode": "ina", "rs_seq": True}, False),
  ]),
  # worst roofline fraction (decode: FSDP param gathers per token)
  "llama3-8b:decode_32k": ([
    ("baseline_fsdp",  {}, {"psum_mode": "xla_spmd"}, False),
    ("ina_manual",     {}, {"psum_mode": "ina"}, False),
    ("bf16_params",    {"param_dtype": "bfloat16"}, {"psum_mode": "ina"}, False),
  ]),
  # memory-bound SSD (bonus cell)
  "zamba2-2.7b:train_4k": ([
    ("baseline",      {}, {"psum_mode": "xla_spmd"}, True),
    ("bf16_scores",   {"__ssm__": {"scores_dtype": "bfloat16"}},
                      {"psum_mode": "xla_spmd"}, True),
    ("bf16_scores_chunk128", {"__ssm__": {"scores_dtype": "bfloat16",
                                          "chunk": 128}},
                      {"psum_mode": "xla_spmd"}, True),
    ("bf16_all",      {"param_dtype": "bfloat16",
                       "__ssm__": {"scores_dtype": "bfloat16"}},
                      {"psum_mode": "ina"}, True),
  ]),
}

out = {}
for cell, variants in CELLS.items():
    arch, shape = cell.split(":")
    rows = []
    for name, co, po, fast in variants:
        try:
            r = measure(arch, shape, mesh, dict(co), dict(po), fast=fast)
            rows.append({"variant": name, "fast": fast,
                         **{k: r[k] for k in ("compute_s","memory_s",
                            "collective_s","dominant","step_s","wall_s")}})
            print(f"RESULT {cell} {name:20s} comp={r['compute_s']:.3f} "
                  f"mem={r['memory_s']:.3f} coll={r['collective_s']:.3f} "
                  f"dom={r['dominant']} step~{r['step_s']:.2f}s "
                  f"[{r['wall_s']}s]", flush=True)
        except Exception as e:
            traceback.print_exc()
            print(f"FAILED {cell} {name}: {e}", flush=True)
        out[cell] = rows
        json.dump(out, open("results/hillclimb.json","w"), indent=1)
print("HILLCLIMB_DONE")
