import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
import json, traceback
from benchmarks.perf_iterations import hillclimb_mesh, measure

mesh = hillclimb_mesh(tp=16, dp=4)
CELLS = {
  "llama4-scout-17b-16e:train_4k": [
    ("ina_manual_ep",  {}, {"psum_mode": "ina"}, False),
    ("ina_bf16params", {"param_dtype": "bfloat16"}, {"psum_mode": "ina"}, False),
    ("bf16_rsseq_ring", {"param_dtype": "bfloat16"},
        {"psum_mode": "ina", "rs_seq": True, "sp_entry": True}, False),
  ],
  "llama3-8b:decode_32k": [
    ("tp_only_params", {}, {"psum_mode": "xla_spmd",
                            "serve_replicated_params": True}, False),
    ("tp_only_bf16",   {"param_dtype": "bfloat16"},
                       {"psum_mode": "xla_spmd",
                        "serve_replicated_params": True}, False),
  ],
}
out = json.load(open("results/hillclimb.json")) if \
    os.path.exists("results/hillclimb.json") else {}
for cell, variants in CELLS.items():
    arch, shape = cell.split(":")
    rows = out.get(cell, [])
    for name, co, po, fast in variants:
        try:
            r = measure(arch, shape, mesh, dict(co), dict(po), fast=fast)
            rows.append({"variant": name, "fast": fast,
                         **{k: r[k] for k in ("compute_s","memory_s",
                            "collective_s","dominant","step_s","wall_s")}})
            print(f"RESULT {cell} {name:20s} comp={r['compute_s']:.3f} "
                  f"mem={r['memory_s']:.3f} coll={r['collective_s']:.3f} "
                  f"dom={r['dominant']} step~{r['step_s']:.2f}s "
                  f"[{r['wall_s']}s]", flush=True)
        except Exception as e:
            traceback.print_exc()
            print(f"FAILED {cell} {name}: {str(e)[:200]}", flush=True)
        out[cell] = rows
        json.dump(out, open("results/hillclimb.json","w"), indent=1)
print("FIXUP_DONE")
